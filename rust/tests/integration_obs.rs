//! Integration: the tracing subsystem's acceptance contract (ISSUE 6).
//!
//! 1. **Reconciliation** — folding the event stream back into per-GPU
//!    Matmul/Other/Comm/Idle breakdowns agrees with the analytically
//!    accumulated ones within 1e-6, on single-replica serving (including
//!    the pipeline-bubble tp4-pp4 shape) and on a multi-replica fleet.
//! 2. **Zero cost when disabled** — attaching a recorder never changes a
//!    simulated number: reports are bit-for-bit identical with tracing
//!    on and off (the traced run merely *adds* the breakdown fields).
//! 3. **Artifact validity** — the emitted Chrome trace parses as JSON
//!    and every (pid, tid) track's timestamps are monotone.

use std::collections::BTreeMap;

use yalis::collectives::AllReduceImpl;
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::obs::{self, chrome, fold, json, Recorder, RunMeta};
use yalis::parallel::ParallelSpec;
use yalis::serving::{fig9_config, serve};
use yalis::trace::TraceSpec;

fn burst_reqs(n: usize) -> Vec<yalis::engine::batcher::Request> {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = n;
    spec.generate()
}

#[test]
fn serve_event_fold_reconciles_with_analytic_breakdown() {
    let reqs = burst_reqs(120);
    for (pspec, ar) in [
        (ParallelSpec::tp(16), AllReduceImpl::Nvrar),
        (ParallelSpec::tp(16), AllReduceImpl::NcclAuto),
        // Pipeline parallelism: the shape with real (bubble) idle inside
        // every step, not just trailing-gap idle.
        (ParallelSpec::tp_pp(4, 4), AllReduceImpl::NcclAuto),
    ] {
        let mut cfg = fig9_config(pspec, ar, 64, "perlmutter", 16);
        let sink = Recorder::sink(RunMeta::default());
        cfg.obs = Some(sink.clone());
        let rep = serve(&cfg, &reqs);
        let label = cfg.deployment_label();
        let bd = rep.breakdown.expect("tracing on -> analytic breakdown present");
        assert!(
            (bd.total() - rep.makespan).abs() < 1e-6,
            "{label}: breakdown total {} vs makespan {}",
            bd.total(),
            rep.makespan
        );
        let rec = sink.lock().unwrap();
        let folded = fold::fold_breakdowns(&rec);
        let drift = fold::reconcile(&[bd], &folded, rec.makespan());
        assert!(drift < 1e-6, "{label}: fold-vs-analytic drift {drift}");
        if pspec.pp > 1 {
            assert!(bd.idle > 0.0, "{label}: pipeline bubbles must show up as idle");
        }
    }
}

#[test]
fn serve_tracing_is_bitwise_zero_cost() {
    let reqs = burst_reqs(100);
    let plain_cfg = fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 64, "perlmutter", 16);
    let plain = serve(&plain_cfg, &reqs);
    assert!(plain.breakdown.is_none(), "tracing off -> no breakdown");
    let mut traced_cfg = plain_cfg.clone();
    let sink = Recorder::sink(RunMeta::default());
    traced_cfg.obs = Some(sink.clone());
    let traced = serve(&traced_cfg, &reqs);
    // Every modeled quantity is bit-identical; recording only observes.
    assert_eq!(plain.makespan.to_bits(), traced.makespan.to_bits());
    assert_eq!(plain.output_throughput.to_bits(), traced.output_throughput.to_bits());
    assert_eq!(plain.mean_ttft.to_bits(), traced.mean_ttft.to_bits());
    assert_eq!(plain.tpot_p50.to_bits(), traced.tpot_p50.to_bits());
    assert_eq!(plain.steps, traced.steps);
    assert_eq!(plain.preemptions, traced.preemptions);
    assert_eq!(plain.total_output_tokens, traced.total_output_tokens);
    // And the recorder did observe the run: one span per step.
    let rec = sink.lock().unwrap();
    assert_eq!(rec.spans().iter().filter(|s| s.name == "step").count() as u64, traced.steps);
}

#[test]
fn fleet_event_fold_reconciles_per_replica_and_is_zero_cost() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 200;
    spec.rate = 10.0;
    let reqs = spec.generate();
    let base = fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 64, "perlmutter", 16);
    let plain = run_fleet(&FleetConfig::new(base.clone(), 3), &reqs);
    assert!(plain.breakdowns.is_empty(), "tracing off -> no per-replica breakdowns");

    let sink = Recorder::sink(RunMeta::default());
    let traced = run_fleet(&FleetConfig::new(base, 3).with_obs(sink.clone()), &reqs);

    // Bit-for-bit identical report, modulo the fields tracing *adds*: the
    // per-replica breakdowns and the exposed/hidden comm accounting (the
    // split is only computed when overlap or tracing asks for it — see
    // `StepCost::step_timing_at`; it never feeds back into a simulated
    // quantity).
    let mut scrubbed = traced.clone();
    scrubbed.breakdowns = Vec::new();
    scrubbed.comm_exposed = 0.0;
    scrubbed.comm_hidden = 0.0;
    assert_eq!(plain, scrubbed, "tracing must not perturb the fleet simulation");

    assert_eq!(traced.breakdowns.len(), 3);
    let rec = sink.lock().unwrap();
    for b in &traced.breakdowns {
        assert!(
            (b.total() - rec.makespan()).abs() < 1e-6,
            "idle-filled breakdown must span the makespan: {} vs {}",
            b.total(),
            rec.makespan()
        );
    }
    let folded = fold::fold_breakdowns(&rec);
    let drift = fold::reconcile(&traced.breakdowns, &folded, rec.makespan());
    assert!(drift < 1e-6, "fleet fold-vs-analytic drift {drift}");
    // The control plane left its marks too.
    let names: Vec<&str> = rec.instants().iter().map(|i| i.name.as_str()).collect();
    for expect in ["arrival", "route", "replica_up", "finish"] {
        assert!(names.contains(&expect), "missing control instant '{expect}'");
    }
}

#[test]
fn chrome_trace_is_valid_json_with_monotone_per_track_timestamps() {
    let reqs = burst_reqs(60);
    let mut cfg = fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 32, "perlmutter", 16);
    let sink = Recorder::sink(RunMeta {
        seed: Some(0xB0257),
        label: String::new(),
        model: String::new(),
        machine: "perlmutter".to_string(),
        ..RunMeta::default()
    });
    cfg.obs = Some(sink.clone());
    serve(&cfg, &reqs);
    let rec = sink.lock().unwrap();
    let text = chrome::to_chrome_json(&rec);
    let v = json::parse(&text).expect("trace must parse as JSON");
    let events = v.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    assert!(!events.is_empty());
    let (mut spans, mut instants, mut metas) = (0usize, 0usize, 0usize);
    let mut last: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("every event has ph");
        if ph == "M" {
            metas += 1;
            continue;
        }
        let pid = ev.get("pid").and_then(|p| p.as_f64()).expect("pid") as u64;
        let tid = ev.get("tid").and_then(|p| p.as_f64()).expect("tid") as u64;
        let ts = ev.get("ts").and_then(|p| p.as_f64()).expect("ts");
        assert!(ts >= 0.0, "timestamps are non-negative microseconds");
        match ph {
            "X" => {
                spans += 1;
                let dur = ev.get("dur").and_then(|d| d.as_f64()).expect("span dur");
                assert!(dur >= 0.0);
            }
            "i" => instants += 1,
            other => panic!("unexpected phase {other:?}"),
        }
        let prev = last.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
        assert!(*prev <= ts, "track ({pid},{tid}): ts {ts} precedes {prev}");
        *prev = ts;
    }
    assert!(spans > 0, "step and collective spans expected");
    assert!(instants > 0, "lifecycle instants expected");
    assert!(metas > 0, "track-naming metadata expected");
}

#[test]
fn write_artifacts_emits_all_three_files_with_meta_headers() {
    let reqs = burst_reqs(40);
    let mut cfg = fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 32, "perlmutter", 16);
    let sink = Recorder::sink(RunMeta {
        seed: Some(0xB0257),
        machine: "perlmutter".to_string(),
        ..RunMeta::default()
    });
    cfg.obs = Some(sink.clone());
    serve(&cfg, &reqs);
    let dir = std::env::temp_dir().join("yalis_obs_integration");
    let base = dir.join("run").to_str().unwrap().to_string();
    let rec = sink.lock().unwrap();
    let paths = obs::write_artifacts(&base, &rec).expect("artifact write");
    assert_eq!(paths.len(), 3);
    for p in &paths {
        let text = std::fs::read_to_string(p).unwrap();
        assert!(!text.is_empty(), "{p} empty");
        if p.ends_with(".trace.json") {
            json::parse(&text).expect("trace JSON parses");
            assert!(text.contains("\"seed\""), "trace carries run metadata");
        } else {
            // CSVs lead with `# key=value` run-metadata comment lines.
            assert!(text.starts_with('#'), "{p} must start with a meta header");
            assert!(text.contains("# seed=0xb0257"), "{p} meta: {text:.120}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
