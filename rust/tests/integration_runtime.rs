//! Integration: PJRT runtime over real artifacts — the three layers
//! composing. Requires `make artifacts`; tests skip (with a note) if the
//! artifacts directory is missing so plain `cargo test` still passes.

// stdout is the product here (CLI tables / bench reports), not stray debug noise.
#![allow(clippy::print_stdout)]

use yalis::collectives::real::Algo;
use yalis::runtime::manifest::Manifest;
use yalis::runtime::tensor::argmax_rows;
use yalis::runtime::tp::TpRuntime;
use yalis::runtime::weights::load_weights;

fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/config.txt").exists() {
        Some("artifacts")
    } else {
        eprintln!("artifacts/ not built; skipping runtime integration test");
        None
    }
}

#[test]
fn manifest_and_weights_consistent() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(dir).unwrap();
    let dims = m.model_dims().unwrap();
    let w = load_weights(&format!("{dir}/weights.bin")).unwrap();
    assert_eq!(w["embed"].dims, vec![dims.vocab, dims.d_model]);
    assert_eq!(w["wq"].dims, vec![dims.n_layers, dims.d_model, dims.q_dim()]);
    assert_eq!(w["wk"].dims, vec![dims.n_layers, dims.d_model, dims.kv_dim()]);
    let total: usize = w.values().map(|t| t.numel()).sum();
    assert_eq!(total, m.get_usize("model.params").unwrap());
}

#[test]
fn sharded_decode_matches_full_model_oracle_via_real_nvrar() {
    let Some(dir) = artifacts() else { return };
    let mut rt = TpRuntime::load(dir).unwrap();
    rt.algo = Algo::Nvrar;
    let b = rt.dims.batch;
    let prompt: Vec<i32> =
        (0..b * rt.dims.prompt).map(|i| ((i * 37 + 11) % rt.dims.vocab) as i32).collect();
    let logits = rt.prefill(&prompt).unwrap();
    assert_eq!(logits.len(), b * rt.dims.vocab);
    let mut toks = argmax_rows(&logits, b);
    for step in 0..3 {
        let full = rt.decode_step_full(&toks).unwrap();
        let sharded = rt.decode_step_sharded(&toks).unwrap();
        for (i, (a, w)) in sharded.iter().zip(&full).enumerate() {
            assert!(
                (a - w).abs() / (1.0 + w.abs()) < 1e-3,
                "step {step} logit {i}: sharded {a} vs full {w}"
            );
        }
        assert_eq!(argmax_rows(&sharded, b), argmax_rows(&full, b));
        toks = argmax_rows(&sharded, b);
    }
    assert_eq!(rt.stats.allreduces, 3 * 2 * rt.dims.n_layers as u64);
}

#[test]
fn sharded_decode_same_result_across_allreduce_algos() {
    let Some(dir) = artifacts() else { return };
    let mut logits_by_algo = Vec::new();
    for algo in [Algo::Nvrar, Algo::Ring, Algo::Central] {
        let mut rt = TpRuntime::load(dir).unwrap();
        rt.algo = algo;
        let b = rt.dims.batch;
        let prompt: Vec<i32> =
            (0..b * rt.dims.prompt).map(|i| ((i * 13 + 5) % rt.dims.vocab) as i32).collect();
        let logits = rt.prefill(&prompt).unwrap();
        let toks = argmax_rows(&logits, b);
        logits_by_algo.push(rt.decode_step_sharded(&toks).unwrap());
    }
    for other in &logits_by_algo[1..] {
        for (a, b) in logits_by_algo[0].iter().zip(other) {
            assert!((a - b).abs() < 1e-4, "algorithms disagree: {a} vs {b}");
        }
    }
}

#[test]
fn gemm_artifacts_execute() {
    let Some(dir) = artifacts() else { return };
    let rt = yalis::runtime::Runtime::cpu().unwrap();
    let exe = rt.load(dir, "gemm_decode_base").unwrap();
    let m = Manifest::load(dir).unwrap();
    let dims: Vec<usize> =
        m.get("gemm.decode.base.mnk").unwrap().split(',').map(|s| s.parse().unwrap()).collect();
    let (mm, nn, kk) = (dims[0], dims[1], dims[2]);
    let x = yalis::runtime::lit_f32(&vec![1.0; mm * kk], &[mm, kk]).unwrap();
    let y = yalis::runtime::lit_f32(&vec![2.0; kk * nn], &[kk, nn]).unwrap();
    let out = exe.run_lits(&[x, y]).unwrap();
    let v = yalis::runtime::to_host_f32(&out[0]).unwrap();
    assert_eq!(v.len(), mm * nn);
    // all-ones x all-twos: every element = 2*K.
    assert!((v[0] - 2.0 * kk as f32).abs() < 1e-2 * kk as f32);
}
