//! Integration: the soak subcommand's determinism contract. Every hot-loop
//! optimization this PR ships (router scratch buffers, step recycling,
//! sorted-percentile caching, fabric watermark pruning) must preserve
//! reports bit for bit — pinned here by hashing the full Debug rendering
//! of each report (f64's Debug is shortest-roundtrip, so two values print
//! identically only when their bits match, modulo the 0.0/-0.0 sign).

use yalis::collectives::AllReduceImpl;
use yalis::coordinator::experiments::{soak_run, SOAK_SEED};
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::parallel::ParallelSpec;
use yalis::serving::{fig9_config, serve};
use yalis::trace::TraceSpec;

/// FNV-1a 64-bit over the value's Debug rendering.
fn digest<T: std::fmt::Debug>(v: &T) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in format!("{v:?}").bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[test]
fn soak_report_digest_is_bit_stable_and_seed_sensitive() {
    // The scaled-down `yalis soak --requests 50000 --replicas 16` run:
    // two executions must produce byte-identical reports, and a different
    // trace seed must change them.
    let (a, _) = soak_run(50_000, 16, SOAK_SEED).expect("soak run");
    let (b, _) = soak_run(50_000, 16, SOAK_SEED).expect("soak run");
    assert_eq!(digest(&a), digest(&b), "soak report drifted between runs");
    assert_eq!(a.completed as u64 + a.rejected, 50_000);
    assert!(a.completed > 0, "the soak fleet must actually serve");
    let (c, _) = soak_run(50_000, 16, SOAK_SEED ^ 0xDEAD).expect("soak run");
    assert_ne!(digest(&a), digest(&c), "seed must reach the whole report");
}

#[test]
fn serve_report_digest_is_bit_stable() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 120;
    let reqs = spec.generate();
    let cfg = fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 32, "perlmutter", 16);
    let a = serve(&cfg, &reqs);
    let b = serve(&cfg, &reqs);
    assert_eq!(digest(&a), digest(&b), "serve report drifted between runs");
    // Contention on with an idle fabric must stay on the same bits too —
    // the watermark-advance optimization prices nothing differently.
    let ca = serve(&cfg.clone().with_contention(), &reqs);
    let cb = serve(&cfg.clone().with_contention(), &reqs);
    assert_eq!(digest(&ca), digest(&cb));
    assert_eq!(a.makespan.to_bits(), ca.makespan.to_bits(), "idle fabric parity");
}

#[test]
fn fleet_report_digest_is_bit_stable_under_contention_and_migration() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 200;
    spec.rate = 12.0;
    let reqs = spec.generate();
    let base = fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, 64, "perlmutter", 16);
    let cfg = || {
        FleetConfig::new(base.clone(), 3)
            .with_contention(true)
            .with_migration(true)
            .with_drain_at(15.0, 2)
    };
    let a = run_fleet(&cfg(), &reqs);
    let b = run_fleet(&cfg(), &reqs);
    assert_eq!(digest(&a), digest(&b), "fleet report drifted between runs");
    assert_eq!(a.completed as u64 + a.rejected, 200);
}
