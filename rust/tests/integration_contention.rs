//! Integration: the shared-interconnect contention layer's acceptance
//! contract.
//!
//! 1. **Closed-form parity** — on an idle fabric the event-driven flow
//!    path reproduces the α-β closed forms (Eqs 1–6) within 1e-9, and an
//!    enabled-but-idle fabric leaves serving/fleet results bit-identical
//!    to the pre-contention path.
//! 2. **Monotonicity** — adding concurrent drain migrations never
//!    *decreases* decode all-reduce time on shared links (property-tested
//!    over random background transfer sets).
//! 3. **The new scenario class** — concurrent KV migration measurably
//!    inflates decode all-reduce / step time, end-to-end through the
//!    fleet, deterministically.

use yalis::cluster::presets;
use yalis::collectives::flows::{allreduce_flow, FlowSpec};
use yalis::collectives::sim::CommConfig;
use yalis::collectives::{model, AllReduceImpl};
use yalis::engine::batcher::StepBatch;
use yalis::fleet::{run_fleet, FleetConfig};
use yalis::models::ModelConfig;
use yalis::obs::{fold, Recorder, RunMeta};
use yalis::parallel::{OverlapSpec, ParallelSpec};
use yalis::serving::{fig9_config, serve, ServeConfig};
use yalis::simnet::{Interconnect, LinkId, LinkKind};
use yalis::trace::TraceSpec;
use yalis::util::prop::{check, Gen};

fn fabric_for(t: &yalis::cluster::Topology) -> Interconnect {
    let mut net = Interconnect::new();
    net.add_scope(0, t.nodes, t.intra.beta, t.inter.beta);
    net
}

fn nic0() -> LinkId {
    LinkId { scope: 0, node: 0, kind: LinkKind::Inter }
}

/// Acceptance: zero-contention event-driven times match the closed-form
/// α-β models within 1e-9 — for every implementation, machine, node count
/// and the paper's message-size band.
#[test]
fn zero_contention_flow_times_match_closed_forms_within_1e9() {
    for machine in ["perlmutter", "vista"] {
        let c = CommConfig::for_machine(machine).unwrap();
        for nodes in [1usize, 2, 4, 8, 16] {
            let t = presets::by_name(machine, nodes).unwrap();
            for kb in [64u64, 128, 512, 1024, 2048] {
                let bytes = kb * 1024;
                let cases: [(AllReduceImpl, f64); 5] = [
                    (AllReduceImpl::NcclRing, model::ring(&t, bytes)),
                    (AllReduceImpl::NcclTree, model::tree(&t, bytes)),
                    (
                        AllReduceImpl::NcclAuto,
                        model::ring(&t, bytes).min(model::tree(&t, bytes)),
                    ),
                    (AllReduceImpl::Mpi, model::recursive_doubling_flat(&t, bytes)),
                    (AllReduceImpl::Nvrar, model::nvrar(&t, bytes, c.eta)),
                ];
                for (which, expect) in cases {
                    let mut net = fabric_for(&t);
                    let f = allreduce_flow(
                        which,
                        &t,
                        &c,
                        FlowSpec { bytes, count: 1.0, scope: 0, at: 0.0 },
                        &mut net,
                    );
                    assert!(
                        (f.alpha_beta - expect).abs() < 1e-9,
                        "{machine} N={nodes} {kb}KB {which:?}: flow {} vs model {expect}",
                        f.alpha_beta
                    );
                    assert_eq!(f.delay, 0.0, "{machine} N={nodes} {kb}KB {which:?}");
                    assert!((f.total() - expect).abs() < 1e-9);
                }
            }
        }
    }
}

/// Acceptance: adding concurrent drain migrations never decreases decode
/// all-reduce time on shared links — property-tested over random
/// background transfer sets, nested one transfer at a time.
#[test]
fn property_concurrent_migrations_never_speed_up_allreduce() {
    check("contention is monotone in background traffic", 30, |g: &mut Gen| {
        let machine = *g.pick(&["perlmutter", "vista"]);
        let nodes = *g.pick(&[2usize, 4, 8]);
        let t = presets::by_name(machine, nodes).unwrap();
        let c = CommConfig::for_machine(machine).unwrap();
        let bytes = *g.pick(&[128u64, 512, 2048]) * 1024;
        let ar = *g.pick(&[AllReduceImpl::Nvrar, AllReduceImpl::NcclAuto, AllReduceImpl::Mpi]);
        let at = g.f64(0.0, 0.05);
        let n_bg = g.usize(0, 8);
        let mut bg: Vec<(f64, f64)> = (0..n_bg)
            .map(|_| (g.f64(0.0, 0.05), g.f64(1e6, 512e6)))
            .collect();
        bg.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut last = 0.0f64;
        for take in 0..=n_bg {
            let mut net = fabric_for(&t);
            for &(start, vol) in bg.iter().take(take) {
                net.book(nic0(), start, vol);
            }
            let f = allreduce_flow(
                ar,
                &t,
                &c,
                FlowSpec { bytes, count: 1.0, scope: 0, at },
                &mut net,
            );
            assert!(
                f.total() >= last - 1e-12,
                "{machine} N={nodes} {ar:?}: background made the all-reduce faster \
                 ({} < {last})",
                f.total()
            );
            last = last.max(f.total());
        }
    });
}

/// The direct mechanism claim: one in-flight KV migration on the shared
/// NIC strictly inflates an overlapping decode all-reduce, and the
/// inflation lands in the congestion accounting.
#[test]
fn concurrent_migration_inflates_decode_allreduce() {
    let t = presets::perlmutter(4); // 16 GPUs
    let c = CommConfig::perlmutter();
    let bytes = 512 * 1024;
    let mut idle = fabric_for(&t);
    let base = allreduce_flow(
        AllReduceImpl::Nvrar,
        &t,
        &c,
        FlowSpec { bytes, count: 1.0, scope: 0, at: 0.0 },
        &mut idle,
    );
    let mut busy = fabric_for(&t);
    busy.book(nic0(), 0.0, 512.0 * 1024.0 * 1024.0); // one migrating context
    let contended = allreduce_flow(
        AllReduceImpl::Nvrar,
        &t,
        &c,
        FlowSpec { bytes, count: 1.0, scope: 0, at: 0.0 },
        &mut busy,
    );
    assert!(
        contended.total() > base.total() * 1.05,
        "migration must measurably inflate the all-reduce: {} vs {}",
        contended.total(),
        base.total()
    );
    assert!(busy.stats().delayed > 0);
    assert!(busy.stats().total_delay > 0.0);
    assert_eq!(idle.stats().delayed, 0);
}

fn base_cfg(conc: usize) -> ServeConfig {
    fig9_config(ParallelSpec::tp(16), AllReduceImpl::Nvrar, conc, "perlmutter", 16)
}

/// Contention disabled is the pre-PR fleet, bit for bit: the default
/// `FleetConfig` has `contention: false`, books nothing, and reports
/// all-zero congestion.
#[test]
fn fleet_contention_off_books_nothing_and_stays_deterministic() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 60;
    spec.rate = 8.0;
    let reqs = spec.generate();
    let cfg = FleetConfig::new(base_cfg(32), 3).disaggregated(1);
    assert!(!cfg.contention, "contention must be opt-in");
    let a = run_fleet(&cfg, &reqs);
    let b = run_fleet(&cfg, &reqs);
    assert_eq!(a, b);
    assert_eq!(a.congestion.bookings, 0);
    assert_eq!(a.net_util_inter, 0.0);
}

/// End-to-end: a disaggregated fleet's continuous prefill→decode KV
/// handoffs share the NICs with the decode all-reduces. With contention
/// on, the fabric registers the traffic, congestion delays accumulate,
/// serving slows measurably versus the closed-form pricing of the *same*
/// trace — and the whole thing is still bit-deterministic.
#[test]
fn fleet_handoff_traffic_inflates_decode_under_contention() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 120;
    spec.rate = 12.0;
    let reqs = spec.generate();
    let build = |contention: bool| {
        FleetConfig::new(base_cfg(32), 2).disaggregated(1).with_contention(contention)
    };
    let off = run_fleet(&build(false), &reqs);
    let on = run_fleet(&build(true), &reqs);
    assert_eq!(off.completed, 120);
    assert_eq!(on.completed, 120);
    assert_eq!(off.output_tokens, on.output_tokens, "contention never loses tokens");
    assert!(on.congestion.bookings > 0, "collectives and handoffs must book the fabric");
    assert!(
        on.congestion.delayed > 0,
        "handoff traffic must contend with decode all-reduces: {:?}",
        on.congestion
    );
    assert!(on.congestion.total_delay > 0.0);
    assert!(on.net_util_inter > 0.0);
    // Congestion slows individual steps/transfers; scheduling can reorder
    // around the margins, so allow sub-percent noise on the aggregate.
    assert!(
        on.makespan >= off.makespan * 0.99,
        "shared links cannot make the fleet meaningfully faster: {} vs {}",
        on.makespan,
        off.makespan
    );
    let again = run_fleet(&build(true), &reqs);
    assert_eq!(on, again, "contention runs must be bit-deterministic");
}

// ---------------------------------------------------------------------
// Sync hiding: the OverlapSpec knob's acceptance contract.
// ---------------------------------------------------------------------

/// FNV-1a 64 over the Debug rendering — "bit-for-bit" for reports.
fn digest<T: std::fmt::Debug>(v: &T) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in format!("{v:?}").bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn decode_step(rows: usize) -> StepBatch {
    StepBatch {
        prefills: vec![],
        decodes: (0..rows as u64).collect(),
        decode_ctx: vec![1024; rows],
    }
}

/// Acceptance: `--overlap 0` is the pre-overlap simulator bit for bit —
/// an explicit zero spec serves identically to the default, on an idle
/// config and on a contended shared fabric alike.
#[test]
fn overlap_zero_serves_bit_identical_reports() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 80;
    let reqs = spec.generate();
    let base = base_cfg(32);
    let a = serve(&base, &reqs);
    let b = serve(&base.clone().with_overlap(OverlapSpec::uniform(0.0)), &reqs);
    assert_eq!(digest(&a), digest(&b), "explicit overlap 0 must match the default");
    // Same claim under contention: identically pre-loaded fabrics.
    let contended = |overlap: OverlapSpec| {
        let cfg = base_cfg(32).with_contention().with_overlap(overlap);
        if let Some(net) = &cfg.net {
            let mut n = net.lock().unwrap();
            for k in 0..8 {
                n.book(nic0(), 0.1 * k as f64, 64.0 * 1024.0 * 1024.0);
            }
        }
        serve(&cfg, &reqs)
    };
    let c = contended(OverlapSpec::none());
    let d = contended(OverlapSpec::uniform(0.0));
    assert_eq!(digest(&c), digest(&d));
    assert!(c.congestion.bookings > 0, "collectives must book the shared fabric");
    // And the run is deterministic, so the digests are meaningful.
    assert_eq!(digest(&a), digest(&serve(&base, &reqs)));
}

/// Full overlap hides communication but never compute: the priced step
/// stays within [serial − comm, serial], strictly below serial, and the
/// exposed/hidden split always re-sums to the serial collective time.
#[test]
fn full_overlap_never_prices_below_pure_compute() {
    for (pspec, rows) in [
        (ParallelSpec::tp(16), 8usize),
        (ParallelSpec::tp(16), 128),
        (ParallelSpec::tp_pp(4, 4), 32),
        (ParallelSpec::tp_pp(8, 2), 64),
    ] {
        let cfg = fig9_config(pspec, AllReduceImpl::Nvrar, 128, "perlmutter", 16);
        let step = decode_step(rows);
        let serial = cfg.step_time(&step);
        let comm = cfg.step_breakdown(&step).comm;
        let full = cfg.clone().with_overlap(OverlapSpec::uniform(1.0));
        let t = full.step_time(&step);
        assert!(
            t >= serial - comm - 1e-12,
            "{pspec:?} x{rows}: overlap cannot hide non-comm time ({t} vs {serial} - {comm})"
        );
        assert!(t < serial, "{pspec:?} x{rows}: full overlap must hide something");
        let sc = full.step_comm(&step);
        assert!(sc.hidden > 0.0, "{pspec:?} x{rows}: {sc:?}");
        assert!(
            (sc.exposed + sc.hidden - comm).abs() < 1e-9,
            "{pspec:?} x{rows}: split must re-sum to serial comm ({sc:?} vs {comm})"
        );
        assert!((serial - t - sc.hidden).abs() < 1e-9, "{pspec:?} x{rows}");
    }
}

/// Step time is monotone non-increasing in the overlap fraction, for the
/// dense, hybrid and MoE cost models alike.
#[test]
fn step_time_is_monotone_in_overlap_fraction() {
    let mut cfgs = vec![
        base_cfg(64),
        fig9_config(ParallelSpec::tp_pp(4, 4), AllReduceImpl::Nvrar, 64, "perlmutter", 16),
    ];
    for (pspec, ar) in yalis::moe::fig10_specs() {
        let mut cfg = fig9_config(pspec, ar, 64, "perlmutter", 16);
        cfg.model = ModelConfig::qwen3_235b_a22b();
        cfgs.push(cfg);
    }
    for cfg in cfgs {
        for rows in [16usize, 64] {
            let step = decode_step(rows);
            let mut last = f64::INFINITY;
            for i in 0..=10 {
                let f = i as f64 / 10.0;
                let t = cfg.clone().with_overlap(OverlapSpec::uniform(f)).step_time(&step);
                assert!(
                    t <= last + 1e-12,
                    "{} x{rows}: step time rose with overlap {f}: {t} > {last}",
                    cfg.deployment_label()
                );
                last = t;
            }
        }
    }
}

/// Contention un-hides communication: with full overlap, background
/// traffic on the shared NIC extends the step and lands in the *exposed*
/// bucket — the fabric still carries the full booked volume either way.
#[test]
fn contention_unhides_overlapped_comm() {
    let step = decode_step(32);
    let timed = |preload: bool| {
        let cfg = base_cfg(32).with_contention().with_overlap(OverlapSpec::uniform(1.0));
        if preload {
            if let Some(net) = &cfg.net {
                net.lock().unwrap().book(nic0(), 0.0, 512.0 * 1024.0 * 1024.0);
            }
        }
        cfg.step_timing_at(&step, 0.0)
    };
    let idle = timed(false);
    let busy = timed(true);
    assert!(idle.booked_bytes > 0.0, "{idle:?}");
    assert_eq!(idle.dur, idle.base, "idle fabric must reproduce the closed form");
    assert!(busy.dur > idle.dur * 1.05, "contention must extend the step: {busy:?} vs {idle:?}");
    assert!(
        busy.comm_exposed > idle.comm_exposed,
        "queueing delay must surface as exposed comm: {busy:?} vs {idle:?}"
    );
    assert_eq!(busy.booked_bytes, idle.booked_bytes, "booked volume is load-independent");
    // A decode step at full overlap has no slack left (comm-bound), so
    // the hidden share cannot grow under load.
    assert!(busy.comm_hidden <= idle.comm_hidden + 1e-12, "{busy:?} vs {idle:?}");
}

/// Booked-vs-exposed accounting closes the loop: the trace fold's
/// per-replica exposed/hidden/booked sums reconcile with the serve
/// report's analytic accumulators within 1e-6, contention and overlap on.
#[test]
fn overlap_comm_accounting_reconciles_with_trace_fold() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 80;
    let reqs = spec.generate();
    let sink = Recorder::sink(RunMeta::default());
    let mut cfg = base_cfg(32).with_contention().with_overlap(OverlapSpec::fig13());
    if let Some(net) = &cfg.net {
        net.lock().unwrap().book(nic0(), 0.0, 128.0 * 1024.0 * 1024.0);
    }
    cfg.obs = Some(sink.clone());
    let rep = serve(&cfg, &reqs);
    assert!(rep.comm_exposed > 0.0);
    assert!(rep.comm_hidden > 0.0, "fig13 overlap must hide comm: {rep:?}");
    assert!(rep.booked_gb > 0.0);
    let rec = sink.lock().unwrap();
    let folded = fold::fold_comm(&rec);
    let analytic = [fold::CommAgg {
        exposed: rep.comm_exposed,
        hidden: rep.comm_hidden,
        booked_gb: rep.booked_gb,
    }];
    let drift = fold::reconcile_comm(&analytic, &folded);
    assert!(drift < 1e-6, "event fold must reconcile with the analytic accounting: {drift}");
}

/// Scripted drain migration under contention: the migration bytes ride
/// the shared NICs and register as congestion against the surviving
/// replicas' decode traffic.
#[test]
fn drain_migration_rides_the_shared_fabric() {
    let mut spec = TraceSpec::burstgpt();
    spec.num_prompts = 60;
    spec.rate = 10.0;
    // Long decodes so real KV context is in flight at drain time.
    spec.output = yalis::trace::LenDist { median: 300.0, sigma: 0.3, min: 64, max: 600 };
    let reqs = spec.generate();
    let cfg = FleetConfig::new(base_cfg(16), 3).with_drain_at(4.0, 2).with_contention(true);
    let rep = run_fleet(&cfg, &reqs);
    assert_eq!(rep.completed, 60);
    assert_eq!(rep.drains, 1);
    assert!(rep.migrations > 0, "in-flight decodes must migrate");
    assert!(rep.congestion.bookings > 0);
    assert!(rep.net_util_inter > 0.0, "migration bytes must land on the NICs");
}
