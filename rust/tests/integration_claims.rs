//! Integration: the paper's headline claims hold end-to-end in the
//! simulation stack (the "shape" contract of the reproduction).

use yalis::cluster::presets;
use yalis::collectives::sim::{self, CommConfig};
use yalis::collectives::AllReduceImpl;
use yalis::coordinator::experiments;
use yalis::engine::persona::Persona;
use yalis::engine::{engine_for, Workload};
use yalis::models::ModelConfig;

/// §5.1/Fig 6: NVRAR beats NCCL in the 256 KB–2 MB range at scale, on both
/// interconnects; bigger wins on InfiniBand (Vista).
#[test]
fn nvrar_speedup_range_matches_paper() {
    for (machine, nodes, min_s, max_s) in
        [("perlmutter", 8usize, 1.05, 2.2), ("vista", 16, 1.5, 4.0)]
    {
        let c = CommConfig::for_machine(machine).unwrap();
        let topo = presets::by_name(machine, nodes).unwrap();
        let mut best: f64 = 0.0;
        for kb in [256u64, 512, 1024] {
            let s = sim::nccl_auto(&topo, &c, kb * 1024).total
                / sim::nvrar(&topo, &c, kb * 1024, 1.0).total;
            assert!(s > 1.05, "{machine} {kb}KB speedup {s}");
            best = best.max(s);
        }
        // 2 MB sits at the top of NVRAR's useful range: still >= breakeven.
        let s2m = sim::nccl_auto(&topo, &c, 2048 * 1024).total
            / sim::nvrar(&topo, &c, 2048 * 1024, 1.0).total;
        assert!(s2m > 0.9, "{machine} 2MB speedup {s2m}");
        assert!(best < max_s, "{machine} best {best} exceeds plausible bound");
        assert!(best > min_s, "{machine} best {best} below paper floor");
    }
}

/// Fig 6 middle: on Perlmutter the microbenchmark (no interleaved compute)
/// shows NVRAR at a disadvantage for 64–128 KB messages.
#[test]
fn small_message_microbench_slowdown_on_perlmutter() {
    let c = CommConfig::perlmutter();
    let topo = presets::perlmutter(4);
    let s64 = sim::nccl_auto(&topo, &c, 64 * 1024).total / sim::nvrar(&topo, &c, 64 * 1024, 0.0).total;
    assert!(s64 < 1.1, "64KB cold speedup should be marginal/negative: {s64}");
    // ...but the e2e workload (interleaved compute) recovers it (App. B).
    let s64_hot =
        sim::nccl_auto(&topo, &c, 64 * 1024).total / sim::nvrar(&topo, &c, 64 * 1024, 1.0).total;
    assert!(s64_hot > s64);
}

/// Fig 7: 1.17x–1.72x e2e speedups for the 405B model decode-heavy.
#[test]
fn e2e_405b_speedups_in_paper_band() {
    let w = Workload::decode_heavy(32);
    for gpus in [32usize, 64] {
        let nccl = engine_for("perlmutter", ModelConfig::llama31_405b(), gpus, "tp",
            Persona::yalis(), AllReduceImpl::NcclAuto).run_batch(&w);
        let nvrar = engine_for("perlmutter", ModelConfig::llama31_405b(), gpus, "tp",
            Persona::yalis(), AllReduceImpl::Nvrar).run_batch(&w);
        let s = nccl.total / nvrar.total;
        assert!(s > 1.05 && s < 2.0, "405B {gpus} GPUs speedup {s}");
    }
}

/// Observation 1 end-to-end: crossover between HP (prefill-heavy) and TP
/// (decode-heavy) on 16 GPUs.
#[test]
fn tp_hp_crossover() {
    let m = ModelConfig::llama31_70b();
    let tp_p = engine_for("perlmutter", m.clone(), 16, "tp", Persona::vllm_v1(), AllReduceImpl::NcclAuto)
        .run_batch(&Workload::prefill_heavy(32));
    let hp_p = engine_for("perlmutter", m.clone(), 16, "hp", Persona::vllm_v0(), AllReduceImpl::NcclAuto)
        .run_batch(&Workload::prefill_heavy(32));
    let tp_d = engine_for("perlmutter", m.clone(), 16, "tp", Persona::vllm_v1(), AllReduceImpl::NcclAuto)
        .run_batch(&Workload::decode_heavy(8));
    let hp_d = engine_for("perlmutter", m, 16, "hp", Persona::vllm_v0(), AllReduceImpl::NcclAuto)
        .run_batch(&Workload::decode_heavy(8));
    assert!(hp_p.total < tp_p.total, "HP should win prefill-heavy: {} vs {}", hp_p.total, tp_p.total);
    assert!(tp_d.total < hp_d.total, "TP should win decode-heavy: {} vs {}", tp_d.total, hp_d.total);
}

/// The event-level sim agrees with the closed-form Eq. 6 when chunking and
/// implementation overheads are disabled.
#[test]
fn sim_vs_closed_form_agreement() {
    use yalis::collectives::model;
    let topo = presets::perlmutter(8);
    let mut c = CommConfig::perlmutter();
    c.block_count = 1;
    c.chunk_bytes = u64::MAX;
    c.put_overhead = 0.0;
    c.nvshmem_overhead = 0.0;
    c.sync_cost = 0.0;
    c.launch_overhead = 0.0;
    c.reduce_bw = f64::INFINITY;
    for kb in [32u64, 128] {
        let sim_t = sim::nvrar(&topo, &c, kb * 1024, 0.0).total;
        let model_t = model::nvrar(&topo, kb * 1024, c.eta);
        let ratio = sim_t / model_t;
        assert!((0.7..1.6).contains(&ratio), "{kb}KB sim/model ratio {ratio}");
        // (At multi-MB sizes the sim intentionally diverges upward: true
        // recursive doubling retransmits the full segment per step, while
        // Eq. 4 charges a single (N-1)/N transfer — see DESIGN.md.)
    }
}

/// Every experiment driver runs and produces non-empty tables (smoke over
/// the full figure registry, minus the slow serving ones).
#[test]
fn experiment_registry_smoke() {
    assert!(!experiments::fig3_breakdown().rows().is_empty());
    assert!(!experiments::table4_gemm_model().rows().is_empty());
    assert!(!experiments::fig4_nccl_vs_mpi().rows().is_empty());
    assert!(!experiments::table5_hyperparams().rows().is_empty());
    assert!(!experiments::fig8_phase_breakdown().rows().is_empty());
    assert!(!experiments::fig13_sync_hiding().rows().is_empty());
    for t in experiments::fig6_microbench("perlmutter") {
        assert!(!t.rows().is_empty());
    }
}
